"""Scenario-ensemble risk demo: stress a DR policy across Monte Carlo
grid futures and read the risk report an operator would sign off on.

Builds a synthetic fleet, generates a mixed scenario ensemble — duck-curve
shape uncertainty, renewable-drought days, evening-ramp spikes, Cambium
2024/2050 projection mixes, fleet composition jitter — and evaluates
CR1 (Efficient) vs CR2 (Fair-Centralized) across ALL scenarios as one
batched XLA call each (`repro.core.api.ensemble`). Prints per-policy
quantiles, CVaR tail risk, fairness dispersion and SLO-violation
probability, then the policy-vs-policy comparison table.

  PYTHONPATH=src python examples/scenario_risk.py \
      [--scenarios 16] [--workloads 16] [--steps 200]
"""
import argparse

from repro.core.api import CR1, CR2, SolveContext, ensemble
from repro.core.ensemble import comparison_table
from repro.core.fleet_solver import synthetic_fleet
from repro.core.scenario import (CambiumMix, DuckPerturb, EveningRampSpike,
                                 FleetJitter, RenewableDrought,
                                 resolve_scenarios)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", type=int, default=16,
                    help="scenarios per generator family")
    ap.add_argument("--workloads", type=int, default=16)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("== Carbon Responder: scenario-ensemble risk report ==")
    fleet = synthetic_fleet(args.workloads, seed=args.seed)
    per = max(1, args.scenarios // 4)
    gens = [DuckPerturb(n_scenarios=max(1, args.scenarios - 3 * per),
                        seed=args.seed),
            RenewableDrought(n_scenarios=per, seed=args.seed + 1),
            EveningRampSpike(n_scenarios=per, seed=args.seed + 2),
            CambiumMix(n_scenarios=per, seed=args.seed + 3)]
    if args.scenarios >= 8:
        gens.append(FleetJitter(n_scenarios=per, seed=args.seed + 4))
    stack = resolve_scenarios(gens, fleet)
    print(f"fleet: {fleet.W} workloads x {fleet.T} h; "
          f"ensemble: {stack.S} scenarios from {len(gens)} generators")
    ctx = SolveContext(steps=args.steps)

    res = ensemble(fleet, CR1(lam=1.45), stack, ctx=ctx)
    rep = res.report()
    print(f"\nCR1 across {res.S} scenarios "
          f"({'one batched XLA call' if res.batched else 'solve loop'}):")
    print("\n".join("  " + ln for ln in rep.lines()))

    print("\npolicy-vs-policy risk comparison "
          "(same scenarios, batched per policy):")
    rep2 = ensemble(fleet, CR2(cap_frac=0.8, outer=2), stack,
                    ctx=ctx).report()
    print("\n".join("  " + ln for ln in comparison_table(
        {rep.policy: rep, rep2.policy: rep2})))

    worst = rep.worst_scenarios[0]
    idx = res.labels.index(worst)
    print(f"\nworst CR1 scenario: {worst} — carbon "
          f"{res.carbon_reduction_pct[idx]:.2f}% vs median "
          f"{float(sorted(res.carbon_reduction_pct)[res.S // 2]):.2f}%")


if __name__ == "__main__":
    main()
