"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
under a Carbon Responder throttle schedule, with fault-tolerant
checkpointing. (CPU-sized here; the same driver scales to the assigned
configs on TPU pods via --arch/--no-reduced.)

  PYTHONPATH=src python examples/train_fleet_dr.py [--steps 200]
"""
import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeCell
from repro.core.carbon import caiso_2021
from repro.core.fleet import FleetCoordinator, FleetJob
from repro.launch.train import train
from repro.power.model import JobPowerModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--lam", type=float, default=1.45)
    args = ap.parse_args()

    # ~100M params: 4 layers, d=384, vocab 32k  (embed 2*12.3M + blocks).
    cfg = reduced(get_config(args.arch), layers=args.layers,
                  d_model=args.d_model, vocab=32768)
    n_params = cfg.param_count()
    print(f"training {args.arch} (reduced): {n_params/1e6:.0f}M params")

    # 1. Fleet plan: this job + a serving neighbor share the pod's power.
    train_job = FleetJob(
        name="train", role="train",
        power=JobPowerModel("train", chips=256, t_compute_s=0.42,
                            t_step_s=0.55))
    serve_job = FleetJob(
        name="serve", role="serve",
        power=JobPowerModel("serve", chips=64, t_compute_s=0.008,
                            t_step_s=0.02))
    coord = FleetCoordinator([train_job, serve_job], caiso_2021(48),
                             lam=args.lam)
    schedules, plan = coord.plan()
    thr = schedules["train"].throttle
    print(f"CR plan: carbon ↓{plan.carbon_reduction_pct:.2f}%, "
          f"penalty {plan.total_penalty_pct:.2f}%; train throttle "
          f"min={thr.min():.2f} mean={thr.mean():.2f}")

    # 2. Train under the throttle schedule (steps-per-hour budgets).
    shape = ShapeCell("example", 256, 8, "train")
    report = train(cfg, shape, steps=args.steps, ckpt_dir="var/ckpt_example",
                   throttle=thr)
    losses = report["losses"]
    print(f"\nsteps={report['steps']}  wall={report['wall_s']:.1f}s  "
          f"{report['steps_per_s']:.2f} steps/s")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(first->last; decreasing={losses[-1] < losses[0]})")
    if report["events"]:
        print(f"runtime events: {report['events'][:5]}")


if __name__ == "__main__":
    main()
