"""Sweep all seven policies (CR1–3, B1–4) and print the Fig.-8 Pareto data
plus the efficiency headline (CR1 ≈ 1.5–2x baselines).

  PYTHONPATH=src python examples/policy_pareto.py
"""
import sys


def main() -> None:
    sys.path.insert(0, ".")
    from benchmarks.common import get_problem, policy_sweeps
    sweep = policy_sweeps()
    by: dict[str, list] = {}
    for r in sweep:
        by.setdefault(r["policy"], []).append(r)
    print(f"{'policy':8s} {'hyper':>7s} {'carbon↓%':>9s} {'penalty%':>9s}")
    for pol in ("CR1", "CR2", "CR3", "B1", "B2", "B3", "B4"):
        for r in sorted(by.get(pol, []), key=lambda x: x["carbon_pct"]):
            print(f"{pol:8s} {r['hyper']:7.3f} {r['carbon_pct']:9.2f}"
                  f" {r['penalty_pct']:9.2f}")
    # efficiency at matched penalty
    def carbon_at(policy, pen_t):
        c = by.get(policy, [])
        return (min(c, key=lambda r: abs(r["penalty_pct"] - pen_t))
                ["carbon_pct"] if c else 0.0)
    for pen_t in (2.0, 4.0):
        cr1 = carbon_at("CR1", pen_t)
        base = max(carbon_at(b, pen_t) for b in ("B1", "B2", "B3", "B4"))
        print(f"\nat ~{pen_t:.0f}% penalty: CR1 removes {cr1:.2f}% carbon vs"
              f" best baseline {base:.2f}% -> {cr1/max(base,1e-9):.2f}x"
              f" (paper: 1.5-2x)")

    # Fleet-engine cross-check: the same CR1 frontier through the unified
    # policy API — the policy grid is a list of values and the whole λ
    # axis is one vmapped XLA call (DRProblem -> FleetProblem via
    # from_problem; SLSQP rows above are the validation reference).
    from repro.core.api import CR1, sweep
    from repro.core.fleet_solver import FleetProblem
    fp = FleetProblem.from_problem(get_problem())
    lams = [1.0, 1.2, 1.45, 1.6, 2.2]
    print("\nCR1 fleet-engine sweep (one compile for the grid):")
    for lam, r in zip(lams, sweep(fp, [CR1(lam=la) for la in lams])):
        print(f"CR1-flt  {lam:7.3f} {r.carbon_reduction_pct:9.2f}"
              f" {r.total_penalty_pct:9.2f}")


if __name__ == "__main__":
    main()
