"""Multi-region fleet demo: one coordinator, three grids.

Builds an R=3 fleet whose regions track the Cambium 2050 mid-case mixes
of California, Texas, and New York (`carbon.regional_traces`, rolled
onto the coordinator's UTC clock so each duck-curve trough lands at its
own hour), then shows the two levers a single-signal coordinator does
not have:

  1. per-region MCI pricing — each region curtails against ITS grid's
     marginal carbon, not a fleet-wide proxy; and
  2. cross-region load migration — deferrable batch slack moves toward
     the momentarily-cleaner region through a `RegionTopology`
     (bandwidth-capped, tolled), either as a host-side post-stage on the
     frozen plan or — `SolveContext(coupled_migration=True)` — refined
     jointly with curtailment inside the AL solve (compared below).

The comparison is at equal total curtailment: each single-signal plan
is scaled down to the multi-region plan's curtailment (a uniformly
down-scaled feasible plan stays feasible), so the gap is pure signal
quality, not extra sacrifice.

  PYTHONPATH=src python examples/multi_region.py

On a multi-device host the same problem shards over a 2-D
(REGION_AXIS, FLEET_AXIS) mesh — `make_fleet_mesh(regions=3)` — with
<0.01 pp parity; see tests/test_fleet_sharding.py.
"""
import dataclasses

import numpy as np

from repro.core.api import CR1, SolveContext, ensemble, solve
from repro.core.fleet_solver import RegionTopology, synthetic_regional_fleet
from repro.core.scenario import RegionalDivergence

STATES = ["CA", "TX", "NY"]


def main() -> None:
    print("== multi-region fleet: CA + TX + NY on one coordinator ==")
    p = synthetic_regional_fleet(9, STATES, hours=48, seed=0,
                                 utc_offsets="auto")
    # a well-interconnected fleet: per-link bandwidth at 15% of fleet
    # entitlement (the synthetic default is a conservative 5%)
    ent = float(np.asarray(p.entitlement).sum())
    bw = np.full((3, 3), 0.15 * ent / 2)
    np.fill_diagonal(bw, 0.0)
    p = dataclasses.replace(
        p, topology=RegionTopology(cost=np.full((3, 3), 1.0), bandwidth=bw,
                                   labels=tuple(STATES)))
    region = np.asarray(p.region)
    mcis = np.asarray(p.mci)
    wmci = mcis[region]
    base = float((np.asarray(p.usage) * wmci).sum())
    print(f"fleet: W={p.W} workloads across R={p.R} regions "
          f"{p.topology.labels}, T={p.T}h")
    for r, s in enumerate(STATES):
        trough = int(np.argmin(mcis[r][:24]))
        print(f"  {s}: {int((region == r).sum())} workloads, cleanest "
              f"hour {trough:02d}:00 UTC, trough/peak "
              f"{mcis[r].min() / mcis[r].max():.2f}")

    ctx = SolveContext(steps=400)
    pol = CR1(lam=1.45)
    multi = solve(p, pol, ctx=ctx)
    curtail = float(np.asarray(multi.D).sum())
    plan = multi.extras["migration"]
    print(f"\nper-region pricing + migration: "
          f"carbon ↓{multi.carbon_reduction_pct:.2f}% "
          f"at {curtail:.0f} NP total curtailment")
    print(f"  migration: moved {plan.moved_total:.1f} NP for "
          f"{plan.carbon_saved:.1f} kgCO2 gross "
          f"- {plan.migration_cost:.1f} toll = {plan.net_saved:.1f} net")
    for r, s in enumerate(STATES):
        out = plan.by_region()[r]
        arrow = "exports" if out > 0 else "imports"
        print(f"  {s}: {arrow} {abs(out):.1f} NP of batch slack")

    # In-loop vs post-stage migration: the post-stage above migrates a
    # FROZEN plan; coupled_migration=True gives the AL solve the
    # interconnect flow variables too, so curtailment can shift toward
    # hours where a profitable (spread > toll) link has spare bandwidth.
    # The coupled candidate is only kept when it beats the post-stage at
    # equal total curtailment — it can match but never lose.
    coup = solve(p, pol,
                 ctx=dataclasses.replace(ctx, coupled_migration=True))
    kept = ("in-loop candidate kept"
            if coup.extras.get("coupled_migration")
            else "post-stage kept (coupled did not beat it)")
    print(f"\nin-loop (coupled) migration: "
          f"↓{coup.carbon_reduction_pct:.2f}% vs post-stage "
          f"↓{multi.carbon_reduction_pct:.2f}% — {kept}")

    # What any ONE signal would have done, scaled to the same total
    # curtailment so the comparison is apples-to-apples.
    print("\nbest single-signal alternative (equal total curtailment):")
    best = -np.inf
    for r, s in enumerate(STATES):
        single = dataclasses.replace(p, mci=mcis[r], region=None,
                                     topology=None)
        rs = solve(single, pol, ctx=ctx)
        realized = 100.0 * float((np.asarray(rs.D) * wmci).sum()) / base
        scale = curtail / float(np.asarray(rs.D).sum())
        print(f"  price everything on {s}: ↓{realized * scale:.2f}%")
        best = max(best, realized * scale)
    print(f"multi-region advantage: "
          f"+{multi.carbon_reduction_pct - best:.2f} pp fleet-wide carbon")

    # Robustness: RegionalDivergence stresses the ensemble layer with
    # per-region level shifts and regional renewable droughts.
    res = ensemble(p, pol, [RegionalDivergence(n_scenarios=8, seed=0)],
                   ctx=SolveContext(steps=300))
    rep = res.report()
    print(f"\nregional-divergence ensemble ({res.S} scenarios): "
          f"carbon p50={rep.carbon_quantiles['p50']:.2f}% "
          f"[p5={rep.carbon_quantiles['p5']:.2f}], "
          f"CVaR25={rep.carbon_cvar:.2f}%")


if __name__ == "__main__":
    main()
