"""Streaming DR demo: rolling-horizon re-solves under forecast revision.

Runs a Carbon Responder fleet *online*: every simulated hour a revised
day-ahead MCI forecast arrives, the coordinator warm-starts the fleet
engine from the previous plan (shifted one hour), re-solves the full
horizon with a fraction of the cold inner-step budget, and commits only
the first hour. Prints per-tick commitments and the realized-vs-forecast
carbon ledger.

  PYTHONPATH=src python examples/streaming_dr.py [--ticks 12] [--policy cr1]

Fleet scale: `--shard` runs every tick's re-solve sharded over all local
devices as one donated-buffer XLA call (workloads padded to the device
count, engine state re-solved in place). On CPU, expose virtual devices
first, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/streaming_dr.py --shard --workloads 10000

One-dispatch day: `--scan` folds the whole run into a single XLA call
(`RollingHorizonSolver.run_scanned` -> `api.solve_day`) — the tick loop
(window roll + plan shift + mu reset + warm re-solve) runs inside
`lax.scan` instead of Python, so a 24-tick day is one donated-buffer
dispatch instead of 24. CR1/CR2 only; parity with the per-tick loop is
<0.01 pp realized carbon:

  PYTHONPATH=src python examples/streaming_dr.py --scan --ticks 24

Observability: `--telemetry out.jsonl` writes the run's structured
event ledger — per-tick `TickEvent`s (forecast revision, warm budget,
latency, committed/realized carbon, recompile counts) plus in-solve
convergence samples captured inside the jitted AL loop — and prints
the report command to render it:

  PYTHONPATH=src python examples/streaming_dr.py --telemetry out.jsonl
  PYTHONPATH=src python -m repro.obs.report out.jsonl
"""
import argparse

from repro.core.api import POLICY_REGISTRY
from repro.core.carbon import ForecastStream
from repro.core.fleet_solver import synthetic_fleet
from repro.core.streaming import RollingHorizonSolver
from repro.obs import TelemetryConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=12)
    ap.add_argument("--workloads", type=int, default=16)
    ap.add_argument("--policy", default="cr1",
                    choices=sorted(POLICY_REGISTRY),
                    help="POLICY_REGISTRY name; the controller resolves it "
                         "to a repro.core.api policy object")
    ap.add_argument("--cold-steps", type=int, default=600)
    ap.add_argument("--warm-steps", type=int, default=150)
    ap.add_argument("--shard", action="store_true",
                    help="shard the W axis over all devices and donate the "
                         "engine state each tick (in-place re-solves)")
    ap.add_argument("--scan", action="store_true",
                    help="whole run as ONE XLA dispatch: the tick loop "
                         "runs inside lax.scan (run_scanned/solve_day; "
                         "CR1/CR2 only)")
    ap.add_argument("--telemetry", metavar="PATH", default=None,
                    help="write the JSONL event ledger (tick events + "
                         "in-solve convergence telemetry) to PATH; render "
                         "with `python -m repro.obs.report PATH`")
    args = ap.parse_args()

    print("== Carbon Responder: rolling-horizon streaming DR ==")
    fleet = synthetic_fleet(args.workloads)
    stream = ForecastStream.caiso(n_ticks=args.ticks, horizon=fleet.T)
    print(f"fleet: {fleet.W} workloads x {fleet.T} h horizon, "
          f"policy {args.policy.upper()}")
    print(f"stream: {args.ticks} hourly forecast revisions "
          f"(sigma={stream.revision_sigma}/sqrt-hour lead error)")
    mesh = None
    if args.shard:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh()
        n = len(mesh.devices.ravel())
        print(f"sharding: {n} devices, "
              f"{-(-fleet.W // n)} workload rows/device, donated ticks")
    print()

    telemetry = None
    if args.telemetry:
        # In-solve convergence traces are CR1/CR2 lanes only; other
        # policies still get the tick ledger.
        telemetry = (TelemetryConfig(every=10)
                     if args.policy in ("cr1", "cr2") else None)
        state = ("on" if telemetry
                 else f"off — {args.policy.upper()} has no traced lane")
        print(f"ledger: {args.telemetry} (telemetry {state})")
    solver = RollingHorizonSolver(
        fleet, stream, policy=args.policy,
        cold_steps=args.cold_steps, warm_steps=args.warm_steps,
        mesh=mesh, donate=args.shard,
        events=args.telemetry, telemetry=telemetry)

    print("tick  start  steps  curtail[NP]  mci fc->act   CO2 fc/act [kg]")

    def show(tk):
        start = "cold" if tk.tick == 0 else "warm"
        print(f"{tk.tick:4d}  {start}  {tk.inner_steps:5d}  "
              f"{tk.committed.sum():11.2f}  "
              f"{tk.forecast_mci:5.0f}->{tk.realized_mci:3.0f}   "
              f"{tk.forecast_carbon:7.1f}/{tk.realized_carbon:7.1f}")

    if args.scan:
        if args.shard:
            raise SystemExit("--scan under --shard is a ROADMAP follow-up "
                             "(the day scan must nest inside the fleet "
                             "shard_map); drop one of the flags")
        report = solver.run_scanned(args.ticks)
        for tk in report.ticks:
            show(tk)
        print(f"\n(one XLA dispatch for all {args.ticks} ticks)")
    else:
        report = solver.run(args.ticks, on_tick=show)

    cold_total = args.cold_steps * args.ticks
    print(f"\ncommitted hours      : {len(report.ticks)}")
    print(f"realized carbon cut  : {report.realized_carbon:.1f} kg "
          f"({report.realized_reduction_pct:.2f}% of baseline)")
    print(f"forecast carbon cut  : {report.forecast_carbon:.1f} kg "
          f"(tracking error {report.forecast_error_pct:.2f}%)")
    print(f"inner steps spent    : {report.total_inner_steps} "
          f"(all-cold would be ~{cold_total}; "
          f"{cold_total / report.total_inner_steps:.1f}x saved)")
    mat = report.committed
    print("\nper-tick committed curtailment (rows = first "
          f"{min(6, mat.shape[0])} workloads):")
    for i in range(min(6, mat.shape[0])):
        line = "".join("▼" if x > 0.05 else ("▲" if x < -0.05 else "·")
                       for x in mat[i])
        print(f"  w{i:02d}: {line}")

    if args.telemetry:
        print(f"\nledger written: {args.telemetry}")
        print(f"render it: PYTHONPATH=src python -m repro.obs.report "
              f"{args.telemetry}")


if __name__ == "__main__":
    main()
