"""Serve a small model with batched requests under Carbon Responder power
caps: shows the QoS ↔ power trade-off the RTS penalty models price.

  PYTHONPATH=src python examples/serve_rts.py
"""
import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.launch.serve import Request, serve_requests
from repro.models import transformer as tf


def main() -> None:
    cfg = reduced(get_config("qwen3-32b"), layers=2, d_model=128, vocab=2048)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def make_requests(n=12):
        return [Request(rid=i,
                        prompt=rng.integers(0, 2000, 12).astype(np.int32),
                        max_new=6) for i in range(n)]

    print("== RTS serving under power caps ==")
    print(f"{'power cap':>10s} {'batch':>6s} {'p50 (s)':>9s} {'p95 (s)':>9s}"
          f" {'tok/s':>8s}")
    for cap_frac, max_batch in ((0.0, 12), (0.2, 6), (0.4, 3)):
        stats = serve_requests(params, cfg, make_requests(),
                               max_batch=max_batch, max_len=32)
        print(f"{cap_frac:10.0%} {max_batch:6d} {stats.p(50):9.3f}"
              f" {stats.p(95):9.3f} {stats.throughput_tok_s:8.1f}")
    print("\n(deeper power caps -> smaller admitted batches -> longer queue"
          "\n delay: the latency degradation the Dynamo-fit cubic penalties"
          "\n price in Carbon Responder's RTS model)")


if __name__ == "__main__":
    main()
