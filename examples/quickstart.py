"""Quickstart: build the paper's four-service fleet, fit penalty models,
and run Carbon Responder through the unified policy API
(`repro.core.api`): policies are values (`CR1(lam=...)`, `CR3(...)`),
`solve()` is the single entry point, `sweep()` runs a whole
hyperparameter grid as one vmapped XLA call, and `ensemble()` evaluates
a policy across a stack of Monte Carlo grid scenarios the same way
(the "Scenario ensembles & risk" section at the end). The closing
section solves a multi-region (region × workload) fleet — per-region
MCI pricing plus cross-region load migration — through the very same
`solve()` call.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.api import CR1, CR3, SolveContext, ensemble, solve, sweep
from repro.core.carbon import caiso_2021
from repro.core.fleet_solver import FleetProblem, fleet_penalties
from repro.core.fleetcache import cached_paper_fleet
from repro.core.metrics import capacity_scaled_entropy
from repro.core.policies import DRProblem
from repro.core.scenario import DuckPerturb, RenewableDrought


def main() -> None:
    print("== Carbon Responder quickstart ==")
    print("building fleet (4 services, EDD-simulated batch penalty models;"
          " cached after first run)...")
    fleet = cached_paper_fleet()
    models = tuple(fleet[n]
                   for n in ("RTS1", "RTS2", "AITraining", "DataPipeline"))
    signal = caiso_2021(48)
    print(f"grid signal: CAISO-2021-shaped MCI, trough/peak = "
          f"{signal.peak_to_trough():.2f}")
    problem = FleetProblem.from_problem(
        DRProblem(models=models, mci=signal.mci))

    print("\nsolving CR1 (Efficient DR) via the unified fleet engine:"
          "\n  result = solve(problem, CR1(lam=1.45))")
    result = solve(problem, CR1(lam=1.45))

    print(f"\ncarbon reduction : {result.carbon_reduction_pct:.2f}% "
          f"of baseline operational carbon (paper Fig. 7: 4.6%)")
    print(f"performance loss : {result.total_penalty_pct:.2f}% "
          f"capacity-equivalent (paper: ~4%)")
    per_pen = np.asarray(fleet_penalties(problem, jnp.asarray(result.D)))
    ent = capacity_scaled_entropy(per_pen, problem.entitlement)
    print(f"fairness entropy : {ent:.2f} (max 2.0)")
    mci = np.asarray(problem.mci)
    base = float((problem.usage.sum(0) * mci).sum())
    print("\nper-service outcome:")
    for i, name in enumerate(problem.names):
        c = 100 * float(result.D[i] @ mci) / base
        q = 100 * per_pen[i] / float(problem.entitlement.sum())
        hours_cut = int((result.D[i] > 0.01 * problem.usage[i]).sum())
        print(f"  {name:13s} carbon ↓{c:5.2f}%  penalty {q:5.2f}%  "
              f"curtailed {hours_cut}/48 hours")
    print("\nhourly adjustment profile (Σ over services, NP):")
    tot = result.D.sum(axis=0)
    for day in range(2):
        line = "".join("▼" if x > 0.3 else ("▲" if x < -0.3 else "·")
                       for x in tot[day * 24:(day + 1) * 24])
        print(f"  day {day}: {line}  (▼ curtail, ▲ boost/recover)")

    # The Fig.-8 trade-off curve: a policy grid is a list of values, and
    # sweep() runs the whole λ axis through one vmapped compile.
    print("\nCR1 λ sweep — sweep(problem, [CR1(lam=l) for l in grid]):")
    grid = (1.2, 1.45, 1.8)
    for lam, r in zip(grid, sweep(problem, [CR1(lam=la) for la in grid],
                                  ctx=SolveContext(steps=300))):
        print(f"  λ={lam:<5g} carbon ↓{r.carbon_reduction_pct:5.2f}%  "
              f"penalty {r.total_penalty_pct:5.2f}%")

    # Decentralized taxes-and-rebates: same entry point, policy-specific
    # outputs (clearing ρ, fiscal balance) ride result.extras.
    print("\nCR3 (Fair-Decentralized) — solve(problem, CR3()):")
    r3 = solve(problem, CR3(), ctx=SolveContext(steps=300))
    print(f"  carbon ↓{r3.carbon_reduction_pct:.2f}%  "
          f"penalty {r3.total_penalty_pct:.2f}%  "
          f"clearing ρ={r3.extras['rho']:.4f}  "
          f"balanced={r3.extras['balanced']}")

    # Scenario ensembles & risk: stress the policy across Monte Carlo
    # grid futures (duck-curve jitter, renewable droughts, Cambium
    # projections — repro.core.scenario) in ONE batched XLA call, then
    # read the risk layer: quantiles, CVaR tail risk, fairness
    # dispersion, SLO-violation probability. See
    # examples/scenario_risk.py for the full report.
    print("\nscenario ensemble — ensemble(problem, CR1(...), generators):")
    res = ensemble(
        problem, CR1(lam=1.45),
        [DuckPerturb(n_scenarios=4), RenewableDrought(n_scenarios=2)],
        ctx=SolveContext(steps=300))
    rep = res.report()
    print(f"  {res.S} scenarios, one batched solve: carbon p50="
          f"{rep.carbon_quantiles['p50']:.2f}% "
          f"[p5={rep.carbon_quantiles['p5']:.2f}], "
          f"CVaR25={rep.carbon_cvar:.2f}%")
    print(f"  fairness (Jain) p50={rep.jain_quantiles['p50']:.2f}, "
          f"SLO breach in {100 * rep.slo_violation_prob:.0f}% "
          f"of scenarios")

    # One-dispatch day: solve_day() runs a whole rolling-horizon day —
    # window roll + plan shift + warm re-solve per tick — inside one
    # lax.scan, so 24 online ticks cost ONE XLA dispatch instead of 24
    # (examples/streaming_dr.py --scan drives the full controller).
    # SolveContext(use_kernel=True) additionally routes the inner Adam
    # loop through the fused al_step Pallas kernel, and
    # moment_dtype="bfloat16" halves the optimizer-state footprint
    # (f32 master iterate, bf16 Adam moments).
    from repro.core.api import solve_day
    mci_stack = np.stack([np.roll(mci, -i) for i in range(4)])
    day = solve_day(problem, CR1(lam=1.45), mci_stack,
                    ctx=SolveContext(use_kernel=True,
                                     moment_dtype="bfloat16"),
                    cold_steps=300)
    print("\none-dispatch day — solve_day(problem, CR1, mci_stack):")
    print(f"  {day.committed.shape[0]} ticks in one XLA call, "
          f"committed NP {day.committed.sum():.1f}, "
          f"steps/tick {list(day.inner_steps)}")

    # Multi-region fleets: a FleetProblem with an (R, T) `mci` prices
    # each region on its own grid trace (Cambium state projections here,
    # rolled onto the coordinator's UTC clock), and a RegionTopology
    # lets solve() migrate deferrable batch slack toward cleaner regions
    # as a host-side post-stage — same entry point, same policies, and
    # R=1 degenerates bitwise to everything above. The full R=3 story
    # (per-region pricing vs the best single signal, migration flows,
    # 2-D device meshes) lives in examples/multi_region.py.
    from repro.core.fleet_solver import synthetic_regional_fleet
    pr = synthetic_regional_fleet(9, ["CA", "TX", "NY"], hours=48,
                                  utc_offsets="auto")
    rr = solve(pr, CR1(lam=1.45), ctx=SolveContext(steps=300))
    plan = rr.extras["migration"]
    print("\nmulti-region fleet — solve(regional_problem, CR1(...)):")
    print(f"  R={pr.R} regions {pr.topology.labels}, W={pr.W} workloads: "
          f"carbon ↓{rr.carbon_reduction_pct:.2f}% "
          f"(migration moved {plan.moved_total:.1f} NP for "
          f"{plan.net_saved:.1f} kgCO2 net)")

    # Coupled migration: SolveContext(coupled_migration=True) moves the
    # interconnect flows INTO the AL solve — curtailment and migration
    # refine jointly against bandwidth caps and tolls, instead of
    # migrating a frozen plan afterwards. The coupled candidate is kept
    # only when it beats the post-stage at equal total curtailment, so
    # this can match but never lose; extras["coupled_migration"] says
    # which stage won.
    rc = solve(pr, CR1(lam=1.45),
               ctx=SolveContext(steps=300, coupled_migration=True))
    kept = "in-loop" if rc.extras.get("coupled_migration") else "post-stage"
    print("\ncoupled migration — SolveContext(coupled_migration=True):")
    print(f"  carbon ↓{rc.carbon_reduction_pct:.2f}% vs post-stage "
          f"↓{rr.carbon_reduction_pct:.2f}% ({kept} candidate kept)")

    # Debugging & sanitizers (repro.analysis): when a solve misbehaves,
    # (1) SolveContext(sanitize=True) reruns the SAME jitted CR1/CR2
    # solve through a checkify twin — a NaN/inf in the gradient,
    # iterate, or multipliers raises SanitizeError naming the first
    # failing check instead of silently corrupting the plan and every
    # warm re-solve after it (~1x overhead, bitwise parity when clean);
    # (2) recompile_guard(0) asserts a region is compile-free, catching
    # the drifting static argument that turns "one trace per tick" into
    # "a compile per tick" (RollingHorizonSolver(guard_recompiles=True)
    # wires it into streaming); (3) `python -m repro.analysis.lint`
    # checks the tree's JAX invariants statically — see
    # src/repro/analysis/README.md for the rulebook.
    from repro.analysis import recompile_guard
    rs = solve(problem, CR1(lam=1.45),
               ctx=SolveContext(steps=300, sanitize=True))
    # Warm re-solve of the opening solve: same static config, warm and
    # cold share one trace, so the guarded block must stay compile-free.
    with recompile_guard(0, label="warm quickstart re-solve"):
        solve(problem, CR1(lam=1.45), ctx=SolveContext(warm=result.state))
    print("\ndebug lane — SolveContext(sanitize=True) + recompile_guard:")
    print(f"  sanitized solve clean (carbon ↓{rs.carbon_reduction_pct:.2f}%"
          f", bitwise = unchecked lane), warm re-solve compile-free")

    # Observability (repro.obs): SolveContext(telemetry=...) captures a
    # convergence trace INSIDE the jitted AL loop — objective, grad
    # norm, max constraint violation, mu — as stacked scan outputs (no
    # host callbacks, no extra dispatches; the returned plan is bitwise
    # identical to a telemetry-off solve). obs.span times host-side
    # work, synchronizing on device results before reading the clock.
    # Streaming runs write a JSONL ledger instead
    # (RollingHorizonSolver(events=..., telemetry=...) or
    # `examples/streaming_dr.py --telemetry run.jsonl`), rendered by
    # `python -m repro.obs.report run.jsonl`.
    from repro import obs
    with obs.span("telemetry solve") as sp:
        rt = sp.bind(solve(problem, CR1(lam=1.45),
                           ctx=SolveContext(
                               steps=300,
                               telemetry=obs.TelemetryConfig(every=30))))
    trace = rt.extras["telemetry"]
    print("\nobservability — SolveContext(telemetry=TelemetryConfig()):")
    print(f"  {trace.n_samples} in-solve samples in {sp.elapsed_s:.2f}s: "
          f"objective {trace.objective[0]:.2f} -> {trace.objective[-1]:.2f},"
          f" grad norm {trace.grad_norm[-1]:.2e} at step {trace.step[-1]}")
    print(f"  plan bitwise = untelemetered solve: "
          f"{bool(np.array_equal(rt.D, rs.D))}")


if __name__ == "__main__":
    main()
