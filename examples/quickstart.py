"""Quickstart: build the paper's four-service fleet, fit penalty models,
run Carbon Responder's CR1 policy for a representative two-day window, and
print the Fig.-7-style outcome.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.carbon import caiso_2021
from repro.core.fleetcache import cached_paper_fleet
from repro.core.metrics import capacity_scaled_entropy
from repro.core.policies import DRProblem, cr1_spec
from repro.core.solver import solve_slsqp


def main() -> None:
    print("== Carbon Responder quickstart ==")
    print("building fleet (4 services, EDD-simulated batch penalty models;"
          " cached after first run)...")
    fleet = cached_paper_fleet()
    models = tuple(fleet[n]
                   for n in ("RTS1", "RTS2", "AITraining", "DataPipeline"))
    signal = caiso_2021(48)
    print(f"grid signal: CAISO-2021-shaped MCI, trough/peak = "
          f"{signal.peak_to_trough():.2f}")
    problem = DRProblem(models=models, mci=signal.mci)

    print("\nsolving CR1 (Efficient DR, scipy SLSQP — the paper's solver)…")
    result = solve_slsqp(cr1_spec(problem, lam=1.45), maxiter=250)

    print(f"\ncarbon reduction : {result.carbon_reduction_pct:.2f}% "
          f"of baseline operational carbon (paper Fig. 7: 4.6%)")
    print(f"performance loss : {result.total_penalty_pct:.2f}% "
          f"capacity-equivalent (paper: ~4%)")
    ent = capacity_scaled_entropy(result.per_penalty, problem.entitlements)
    print(f"fairness entropy : {ent:.2f} (max 2.0)")
    print("\nper-service outcome:")
    for i, name in enumerate(problem.names):
        c = 100 * result.per_carbon[i] / problem.total_carbon_baseline
        q = 100 * result.per_penalty[i] / problem.entitlements.sum()
        hours_cut = int((result.D[i] > 0.01 * problem.usage[i]).sum())
        print(f"  {name:13s} carbon ↓{c:5.2f}%  penalty {q:5.2f}%  "
              f"curtailed {hours_cut}/48 hours")
    print("\nhourly adjustment profile (Σ over services, NP):")
    tot = result.D.sum(axis=0)
    for day in range(2):
        line = "".join("▼" if x > 0.3 else ("▲" if x < -0.3 else "·")
                       for x in tot[day * 24:(day + 1) * 24])
        print(f"  day {day}: {line}  (▼ curtail, ▲ boost/recover)")


if __name__ == "__main__":
    main()
